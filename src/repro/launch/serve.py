"""Serving launcher: load a (quantized) checkpoint and serve batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_12b --reduce \
        --ckpt-dir /tmp/repro_quant --requests 8 --engine paged

``--engine paged`` (default for self-attention decoder archs) serves from
the paged-KV engine — shared page pool, chunked prefill, prefix caching,
SLO-aware scheduling; ``--engine contiguous`` keeps the per-slot max_seq
reservation baseline (and is the only choice for enc-dec / SSM-hybrid
archs — the fallback warns loudly, and ``--strict-engine`` turns it into a
hard error for deployments that must not silently lose paging).

SLO knobs (paged engine): ``--deadline-ms`` attaches a per-request
deadline, ``--priority`` a scheduling priority; requests finish with a
terminal status (completed / preempted_resumed / shed / deadline_missed).
``--fault-plan`` activates seeded fault injection (repro.faults) for chaos
drills.

Speculative decoding (paged engine, DESIGN.md §Speculative-serving):
``--speculate`` turns on self-speculative greedy decode — a draft stack
proposes ``--gamma`` tokens per round into draft-owned pages of the same
pool and one fused target forward verifies; output is token-identical to
non-speculative greedy.  The draft comes from ``--draft-layers K`` (the
first K periods of the served artifact — zero extra weight memory),
``--draft-bits B`` (on-the-fly RTN of the loaded dense checkpoint via
serve/qparams.rtn_quantize_for_serving), ``--draft-checkpoint DIR`` (a
separately trained/quantized stack), or combinations (bits/checkpoint
then truncated by ``--draft-layers``).  With no source given,
``--speculate`` defaults to truncating the served stack at half depth.
"""

import argparse
import sys


def _positive_int(name):
    """argparse type: strictly positive integer with a pointed error."""
    def parse(s):
        try:
            v = int(s)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"{name} expects a positive integer, got {s!r}"
            )
        if v <= 0:
            raise argparse.ArgumentTypeError(
                f"{name} must be >= 1, got {v} — 0 or negative would serve "
                "nothing (use a positive count)"
            )
        return v
    return parse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--quantized", action="store_true",
                    help="checkpoint holds fake-quant/dense params either way;"
                         " flag is informational")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=_positive_int("--max-new"), default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--engine", choices=["paged", "contiguous"], default="paged")
    ap.add_argument("--strict-engine", action="store_true",
                    help="hard-error instead of falling back to the "
                         "contiguous engine when --engine paged is "
                         "unavailable for the arch")
    ap.add_argument("--page-size", type=_positive_int("--page-size"), default=16)
    ap.add_argument("--n-pages", type=int, default=0,
                    help="KV pool size in pages (0 = ample: no preemption)")
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--kv-dtype", choices=["bf16", "int8", "int4"], default="bf16",
                    help="KV cache storage; int4 packs two codes/byte and is "
                         "paged-engine only")
    ap.add_argument("--scheduler", choices=["slo", "fifo"], default="slo",
                    help="paged-engine scheduling policy (fifo = legacy "
                         "arrival order + preempt-newest)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request SLO deadline in ms (0 = none); "
                         "unmeetable requests are shed, overdue ones "
                         "finish as deadline_missed")
    ap.add_argument("--priority", type=int, default=0,
                    help="request priority (higher = more urgent; low-"
                         "priority work parks under pool pressure)")
    ap.add_argument("--fault-plan", default="",
                    help="fault-injection plan: path to a JSON spec or an "
                         "inline JSON string (see repro.faults.FaultPlan)")
    ap.add_argument("--speculate", action="store_true",
                    help="self-speculative greedy decode (paged engine only; "
                         "token-identical output)")
    ap.add_argument("--gamma", type=_positive_int("--gamma"), default=4,
                    help="draft tokens proposed per speculative round")
    ap.add_argument("--draft-layers", type=_positive_int("--draft-layers"),
                    default=None,
                    help="truncated self-draft: first K periods of the "
                         "served stack (zero extra weight memory)")
    ap.add_argument("--draft-bits", type=_positive_int("--draft-bits"),
                    default=None,
                    help="RTN-quantize the loaded dense checkpoint to this "
                         "many bits as the draft stack")
    ap.add_argument("--draft-checkpoint", default="",
                    help="serve the draft from a separate checkpoint dir "
                         "(same arch)")
    args = ap.parse_args()

    from repro.faults import FaultPlan, fault_plan

    plan_obj = FaultPlan.from_spec(args.fault_plan) if args.fault_plan else None
    if plan_obj is not None:
        print(f"fault plan active: seed={plan_obj.seed}, "
              f"{len(plan_obj.specs)} spec(s)")
    with fault_plan(plan_obj):
        _run(args)


def _run(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.dist import checkpoint as ckpt
    from repro.launch.train import reduced
    from repro.models import make_plan, param_shapes
    from repro.serve.engine import PagedServingEngine, Request, ServingEngine

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    plan = make_plan(cfg, 1, kv_cache_dtype=args.kv_dtype)
    like_params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), param_shapes(plan)
    )

    def load_params(ckpt_dir):
        try:  # quantized/eval checkpoints hold params only …
            state, manifest = ckpt.load_checkpoint(
                ckpt_dir, {"params": like_params}
            )
        except ValueError:  # … train checkpoints also carry optimizer state
            from repro.train.optimizer import AdamWConfig, adamw_init

            state, manifest = ckpt.load_checkpoint(
                ckpt_dir,
                {"params": like_params,
                 "opt": adamw_init(like_params, AdamWConfig())},
            )
        return state["params"], manifest

    try:
        params, manifest = load_params(args.ckpt_dir)
        print(f"loaded step {manifest['step']}")
    except FileNotFoundError:
        from repro.models import init_params

        print("no checkpoint found — serving random init (demo)")
        params = init_params(plan, jax.random.PRNGKey(0))

    # Roofline-selected weight layout (serve/qparams.py): packed-4-bit
    # QuantizedTensor leaves may re-permute into the GEMM kernel's
    # tile-native order.  Dense/bf16 checkpoints pass through untouched.
    from repro.serve.qparams import prepack_params_for_serving

    params, layout_decisions = prepack_params_for_serving(plan, params)
    if layout_decisions:
        labels = sorted(set(layout_decisions.values()))
        print(f"weight pack layout ({jax.default_backend()}): "
              + ", ".join(f"{lb} ×{sum(1 for v in layout_decisions.values() if v == lb)}"
                          for lb in labels))
    else:
        print("weight pack layout: linear (no packed 4-bit weight leaves)")

    if args.kv_dtype == "int4" and args.engine != "paged":
        raise SystemExit(
            "--kv-dtype int4 requires --engine paged: int4 KV lives in packed "
            "pages (quant/pack.kv_pack_int4); the contiguous engine supports "
            "bf16/int8 only"
        )
    rng = np.random.default_rng(0)
    if args.engine == "paged":
        try:  # probe arch support only — config errors must still surface
            from repro.models import paged_cache_shapes

            paged_cache_shapes(plan, 2, args.page_size)
        except ValueError as e:  # enc-dec / SSM-hybrid / prefix archs
            if args.kv_dtype == "int4":
                # No silent downgrade: the contiguous fallback cannot hold
                # int4 pages, so the request is unsatisfiable as stated.
                raise SystemExit(
                    f"--kv-dtype int4 unavailable for {args.arch}: {e}"
                )
            if args.strict_engine:
                raise SystemExit(
                    f"--strict-engine: paged engine unavailable for arch "
                    f"{args.arch!r} ({e}) and fallback is disabled"
                )
            print(
                f"WARNING: paged engine unavailable for arch {args.arch!r} "
                f"({e}) — FALLING BACK to the contiguous engine: no paged "
                "KV pool, no prefix cache, no SLO preemption; per-slot "
                "max_seq KV is reserved up front (pass --strict-engine to "
                "make this a hard error)",
                file=sys.stderr,
            )
            args.engine = "contiguous"
    if args.speculate and args.engine != "paged":
        # No silent downgrade: draft pages live in the paged pool, so
        # speculation cannot run on the contiguous engine.
        raise SystemExit(
            "--speculate requires the paged engine (draft tokens decode "
            "into draft-owned pages of the shared pool); it is unavailable "
            f"with --engine {args.engine} for arch {args.arch!r}"
        )
    spec = None
    if args.speculate:
        from repro.serve.qparams import rtn_quantize_for_serving
        from repro.serve.spec import SpecConfig, truncate_draft

        draft_plan, draft_params = plan, params
        if args.draft_checkpoint:
            draft_params, d_manifest = load_params(args.draft_checkpoint)
            print(f"draft checkpoint: step {d_manifest['step']}")
        if args.draft_bits:
            draft_params, d_layout = rtn_quantize_for_serving(
                plan, draft_params, bits=args.draft_bits
            )
            print(f"draft: {args.draft_bits}-bit RTN [{d_layout}]")
        k = args.draft_layers
        if k is None and not args.draft_bits and not args.draft_checkpoint:
            k = max(1, cfg.n_periods // 2)
            print(f"--speculate with no draft source: truncated self-draft "
                  f"at {k}/{cfg.n_periods} periods")
        if k is not None:
            if k >= cfg.n_periods:
                raise SystemExit(
                    f"--draft-layers {k} must be < the target's "
                    f"{cfg.n_periods} periods — a full-depth draft is the "
                    "target itself and speculation would only add overhead"
                )
            draft_plan, draft_params = truncate_draft(
                draft_plan, draft_params, k
            )
        spec = SpecConfig(draft_plan=draft_plan, draft_params=draft_params,
                          gamma=args.gamma)
    if args.engine == "paged":
        eng = PagedServingEngine(
            plan, params, max_batch=args.max_batch, max_seq=512,
            page_size=args.page_size, n_pages=args.n_pages or None,
            prefill_chunk=args.prefill_chunk, scheduler=args.scheduler,
            spec=spec,
        )
    else:
        eng = ServingEngine(plan, params, max_batch=args.max_batch, max_seq=512)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 32)).astype(np.int32)
        eng.submit(Request(
            rid=i, prompt=prompt, max_new_tokens=args.max_new,
            deadline_ms=args.deadline_ms or None, priority=args.priority,
        ))
    finished = eng.run()
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"req{r.rid} [{r.status}]: prompt[{len(r.prompt)}] -> {r.output}")
    if args.engine == "paged":
        print(f"{len(finished)} requests, {eng.n_decode_steps} decode steps, "
              f"{eng.n_prefill_chunks} prefill chunks "
              f"({eng.n_prefix_hit_tokens} prefix-cached tokens, "
              f"{eng.n_preemptions} preemptions, {eng.n_shed} shed, "
              f"{eng.n_deadline_missed} deadline-missed)")
        if args.speculate:
            acc = eng.acceptance_rate()
            print(f"speculative: {eng.n_spec_rounds} rounds, "
                  f"{eng.n_draft_accepted}/{eng.n_draft_tokens} draft tokens "
                  f"accepted (rate "
                  f"{'-' if acc is None else format(acc, '.3f')}, γ="
                  f"{args.gamma})")
    else:
        print(f"{len(finished)} requests, {eng.n_decode_steps} decode steps, "
              f"{eng.n_prefills} prefills")


if __name__ == "__main__":
    main()
