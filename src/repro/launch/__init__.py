"""Launchers: mesh construction, dry-run, train/serve/quantize CLIs."""
