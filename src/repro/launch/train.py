"""Training launcher.

CPU / small runs:
    PYTHONPATH=src python -m repro.launch.train --arch stablelm_12b \
        --reduce --steps 100

Cluster runs (the dry-run proves the lowering; on hardware the same entry
point executes): drop ``--reduce``, set ``--mesh single|multi`` — jax
devices must match (real TPU slices; here only the dry-run exercises it).
"""

import argparse
import dataclasses


def reduced(cfg):
    kw = dict(
        d_model=128,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab=256,
        n_periods=2,
        max_seq=1024,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2),
        moe_d_ff=256 if cfg.n_experts else 0,
        ssm_state=16,
        ssm_headdim=16,
        n_enc_periods=2 if cfg.n_enc_periods else 0,
        n_frames=64 if cfg.family == "encdec" else 1500,
        n_prefix=16 if cfg.n_prefix else 0,
    )
    return dataclasses.replace(cfg, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduce", action="store_true", help="CPU-sized config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--moments", default="fp32", choices=["fp32", "int8"])
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=args.lr, total_steps=args.steps, moments=args.moments),
        TrainerConfig(
            steps=args.steps,
            batch=args.batch,
            seq=args.seq,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=max(args.steps // 4, 1),
            n_microbatches=args.microbatches,
        ),
    )
    out = trainer.run()
    loss = out["final_loss"]  # None when steps < the metrics-log interval
    print(f"final loss: {'n/a' if loss is None else f'{loss:.4f}'}  "
          f"recoveries: {out['recoveries']}")
    for m in out["log"]:
        print(m)


if __name__ == "__main__":
    main()
