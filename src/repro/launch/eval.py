"""Eval launcher: score a (quantized) model end to end — the paper's tables.

Loads a trained checkpoint, sweeps a method × bits (× outlier budget) grid
through the whole-model PTQ driver (each cell quantizes in-process and is
scored as the restacked QuantizedTensor serving artifact), and measures on
the ``split="eval"`` stream — disjoint from the ``split="calib"`` stream by
construction (data/pipeline.py):

  * perplexity / NLL (Tables 1-3, 5 shape),
  * cloze next-token top-1/top-5 and multi-choice continuation accuracy
    (the zero-shot task family, §5.3 shape),
  * scorer-vs-serving-engine logit parity (the numbers describe what the
    engines actually execute; see repro/eval/harness.py for the tolerance).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_12b \
        --reduce --steps 100 --ckpt-dir /tmp/repro_train
    PYTHONPATH=src python -m repro.launch.eval --arch stablelm_12b \
        --reduce --ckpt-dir /tmp/repro_train --bits 4 3 \
        --methods rtn gptq quantease --outlier-bits 3 --out /tmp/eval.json

``--smoke`` shrinks the grid and budgets to seconds (schema unchanged —
the CI smoke path; the committed ``BENCH_eval.json`` comes from
``benchmarks/bench_eval.py``, which drives the same harness on the shared
trained benchmark model).
"""

import argparse
import json


def main():
    ap = argparse.ArgumentParser(
        description="End-to-end quantized-model evaluation (ppl + tasks + parity)."
    )
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true",
                    help="CPU-sized config (same reduction as launch/train.py)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--out", default="/tmp/repro_eval/eval.json")
    ap.add_argument("--methods", nargs="+", default=["rtn", "gptq", "quantease"])
    ap.add_argument("--bits", type=int, nargs="+", default=[4, 3])
    ap.add_argument("--outlier-bits", type=int, default=0, metavar="B",
                    help="add a qe_outlier cell at B bits (0 = off)")
    ap.add_argument("--outlier-frac", type=float, default=0.01)
    ap.add_argument("--group-size", type=int, default=0)
    ap.add_argument("--iterations", type=int, default=20)
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-seed", type=int, default=0,
                    help="corpus seed — must match the TRAINING corpus "
                         "(launch/train.py TrainerConfig.seed, default 0): "
                         "it fixes the Markov chain itself, not just the stream")
    ap.add_argument("--emit", choices=["qt", "fake"], default="qt",
                    help="score the QuantizedTensor serving artifact (qt) or "
                         "the dequantized bf16 tree (fake)")
    ap.add_argument("--no-parity", action="store_true",
                    help="skip the serving-engine logit parity check")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale budgets, 2-cell grid (schema unchanged)")
    args = ap.parse_args()

    import os

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, make_batch_fn
    from repro.dist import checkpoint as ckpt
    from repro.eval import EVAL_SCHEMA, quantized_parity, run_grid, validate_doc
    from repro.eval.harness import EvalBudget
    from repro.launch.train import reduced
    from repro.models import init_params, make_plan, param_shapes

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    plan = make_plan(cfg, 1)
    like_params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                               param_shapes(plan))
    try:
        try:  # quantized/eval checkpoints hold params only …
            state, manifest = ckpt.load_checkpoint(
                args.ckpt_dir, {"params": like_params}
            )
        except ValueError:  # … train checkpoints also carry optimizer state
            from repro.train.optimizer import AdamWConfig, adamw_init

            state, manifest = ckpt.load_checkpoint(
                args.ckpt_dir,
                {"params": like_params,
                 "opt": adamw_init(like_params, AdamWConfig())},
            )
        params = state["params"]
        print(f"loaded checkpoint step {manifest['step']}")
    except FileNotFoundError:
        print("no checkpoint found — evaluating random init (smoke/demo only)")
        params = init_params(plan, jax.random.PRNGKey(0))

    dc = DataConfig(vocab=cfg.vocab, seed=args.data_seed)
    calib_fn, _ = make_batch_fn(dc, cfg, batch=4, seq=args.seq, split="calib")
    eval_fn, corpus = make_batch_fn(dc, cfg, batch=4, seq=args.seq, split="eval")
    n_calib = 1 if args.smoke else args.calib_batches
    calib = [
        {k: jnp.asarray(v) for k, v in calib_fn(i).items()} for i in range(n_calib)
    ]

    if args.smoke:
        cells = [
            {"method": "rtn", "bits": 4},
            {"method": "quantease", "bits": 3, "iterations": 2},
        ]
        budget = EvalBudget.smoke()
    else:
        cells = [
            {"method": m, "bits": b, "group_size": args.group_size or None}
            for b in args.bits for m in args.methods
        ]
        if args.outlier_bits:
            cells.append({
                "method": "qe_outlier", "bits": args.outlier_bits,
                "outlier_frac": args.outlier_frac,
            })
        budget = EvalBudget(n_ppl_batches=args.eval_batches)

    def progress(rec):
        print(f"[{rec['cell']}] ppl={rec.get('ppl', 0):.4f} "
              f"top1={rec.get('top1', 0):.3f} choice={rec.get('choice_acc', 0):.3f}")

    iterations = 2 if args.smoke else args.iterations
    doc = {
        "schema": EVAL_SCHEMA,
        "smoke": bool(args.smoke),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "arch": args.arch,
        "data": {
            "vocab": cfg.vocab, "seq": args.seq,
            "eval_split": "eval", "calib_split": "calib",
            "entropy_floor_ppl": round(float(np.exp(corpus.entropy_floor())), 4),
        },
        "iterations": iterations,
        "emit": args.emit,
    }
    doc.update(run_grid(
        plan, params, calib, eval_fn, cells,
        iterations=iterations, emit=args.emit, budget=budget,
        progress_cb=progress,
    ))
    if args.no_parity:
        doc["parity"] = None
    else:
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in (5, 13, 29)]
        doc["parity"] = quantized_parity(
            plan, params, calib, prompts,
            iterations=2 if args.smoke else 6,
        )
        print(f"parity: {doc['parity']}")

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")
    # Validation runs regardless of --no-parity: a full doc without parity
    # (or with broken orderings) should warn here exactly as
    # bench_eval.py --validate would fail on it later.
    if not doc["smoke"]:
        for p in validate_doc(doc):
            print(f"WARNING: {p}")


if __name__ == "__main__":
    main()
