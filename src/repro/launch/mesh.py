"""Production meshes (fixed by contract — see the dry-run spec).

Functions, not module constants: importing this module must never touch
jax device state.
"""

from __future__ import annotations

from typing import Optional

import jax

__all__ = ["make_production_mesh", "make_data_mesh", "mesh_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_data_mesh(n: Optional[int] = None):
    """1-D ("data",) mesh over the first ``n`` local devices (default: all).

    The PTQ driver's sharding unit (launch/quantize.py --shard): calibration
    Gram accumulation splits batch rows over it, the CD solve splits output
    rows over it.  Returns None for a single device — callers treat None as
    "run the local fallback path".
    """
    n = len(jax.devices()) if n is None else n
    if n <= 1:
        return None
    return jax.make_mesh(
        (n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,)
    )


def mesh_devices(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
