"""Production meshes (fixed by contract — see the dry-run spec).

Functions, not module constants: importing this module must never touch
jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_devices(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
