"""PTQ launcher: quantize a trained checkpoint with any paper method.

Runs the streaming, sharded whole-model engine (core/solver.py): the
capture pass accumulates Σ = XXᵀ per linear batch-by-batch (never raw
activations), same-shape linears solve in batched vmapped calls, and with
``--shard`` both the Gram accumulation and the coordinate-descent solve
split across all local devices (single-device runs take the identical
local fallback automatically).

Flags beyond the model/method basics:

* ``--shard`` — build a 1-D ("data",) mesh over every local device;
  calibration batches data-shard with psum'd Σ accumulation and the CD
  solve shard_maps over output rows.  A no-op on one device.
* ``--stream-calib N`` — feed the capture pass at most N sequences at a
  time (0 = whole calibration batch at once).  Transient activation memory
  during capture becomes O(N·seq·p) regardless of ``--calib-batches``.
  For dense linears the accumulated Σ is identical either way; MoE layers
  compute dispatch capacity per forward, so chunking can change which
  overflow tokens drop and perturb the per-expert Σ slightly (same effect
  as choosing a different calibration batch size).
* ``--resume`` — report progress from a previous run's ``progress.jsonl``
  in the output dir before starting (block-level audit trail of what
  completed and the per-block error summary), then restart from scratch.
  The whole pipeline is deterministic for fixed flags — calibration batch
  ``i`` is a pure function of ``(seed, "calib", i)`` and the CD solve has
  no RNG — so a restart emits a **bit-identical** artifact to the
  uninterrupted run (tests/test_chaos.py pins this).
* ``--fault-plan`` — activate a seeded fault-injection plan
  (repro.faults) for chaos testing; transient faults in the calibration
  fetch are absorbed by a retry loop, a corrupted source checkpoint falls
  back to the last good step.

Resilience (DESIGN.md §Resilience): the source checkpoint loads through
``load_last_good`` (CRC-verified, damaged steps skipped with a warning),
and the calibration fetch runs under ``dist/elastic.RetryingRunner`` —
a transient storage fault restarts the (deterministic) fetch instead of
killing the run.

Progress: one line + one ``progress.jsonl`` record per quantized block
(stack, period, block index, linears solved, mean relative error, seconds).

End-to-end on the reduced CPU configs (quickstart-sized, ~a minute):

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_12b \
        --reduce --steps 20 --ckpt-dir /tmp/repro_train
    PYTHONPATH=src python -m repro.launch.quantize --arch stablelm_12b \
        --reduce --ckpt-dir /tmp/repro_train --method quantease --bits 3 \
        --stream-calib 2 --shard
"""

import argparse
import json
import os
import sys

# Historical home of the torn-tail-tolerant progress parser; the shared
# implementation now lives in repro.launch.progress (tune.py and the resume
# paths import it from there) — re-exported so existing imports keep working.
from repro.launch.progress import append_record, load_progress  # noqa: F401


def main():
    ap = argparse.ArgumentParser(
        description="Whole-model PTQ with the streaming/sharded QuantEase engine."
    )
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true",
                    help="CPU-sized config (same reduction as launch/train.py)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--out-dir", default="/tmp/repro_quant")
    ap.add_argument("--method", default="quantease",
                    choices=["rtn", "gptq", "awq", "quantease", "awq_qe",
                             "spqr", "qe_outlier", "qe_outlier_struct"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--iterations", type=int, default=25)
    ap.add_argument("--outlier-frac", type=float, default=0.01)
    ap.add_argument("--group-size", type=int, default=0)
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-seed", type=int, default=0,
                    help="corpus seed — must match the TRAINING corpus "
                         "(launch/train.py TrainerConfig.seed, default 0)")
    ap.add_argument("--shard", action="store_true",
                    help="shard Σ accumulation + CD solve over all local devices")
    ap.add_argument("--stream-calib", type=int, default=0, metavar="N",
                    help="capture-pass chunk size in sequences (0 = whole batch)")
    ap.add_argument("--resume", action="store_true",
                    help="report a previous run's block progress before starting")
    ap.add_argument("--fault-plan", default="",
                    help="fault-injection plan: path to a JSON spec or an "
                         "inline JSON string (see repro.faults.FaultPlan)")
    args = ap.parse_args()

    from repro.faults import FaultPlan, fault_plan

    plan_obj = FaultPlan.from_spec(args.fault_plan) if args.fault_plan else None
    if plan_obj is not None:
        print(f"fault plan active: seed={plan_obj.seed}, "
              f"{len(plan_obj.specs)} spec(s)")
    with fault_plan(plan_obj):
        _run(args)


def _run(args):
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.solver import PTQConfig, ptq_quantize_model
    from repro.data.pipeline import DataConfig, make_batch_fn
    from repro.dist import checkpoint as ckpt
    from repro.dist.elastic import RetryingRunner
    from repro.launch.mesh import make_data_mesh
    from repro.launch.train import reduced
    from repro.models import make_plan, param_shapes
    from repro.quant import GridSpec
    from repro.train.optimizer import AdamWConfig, adamw_init

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    plan = make_plan(cfg, 1)

    import jax

    progress_path = os.path.join(args.out_dir, "progress.jsonl")
    if args.resume:
        # Tolerant parse: a run killed mid-write leaves an empty file or a
        # torn last line — resume from the last *complete* record.
        lines = load_progress(progress_path)
        if lines:
            last = lines[-1]
            print(
                f"previous run: {last['done_blocks']}/{last['total_blocks']} blocks "
                f"({last['stack']}.p{last['period']}.b{last['block']}), "
                f"mean_err={last['mean_rel_error']:.4g} — restarting from scratch"
            )
        else:
            print("previous run: no complete progress records — cold start")
    # Each run owns its progress file: truncate so records never interleave
    # across runs (with or without --resume).
    if os.path.exists(progress_path):
        os.remove(progress_path)

    like_params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), param_shapes(plan)
    )
    like = {"params": like_params, "opt": adamw_init(like_params, AdamWConfig())}
    state, manifest, skipped = ckpt.load_last_good(args.ckpt_dir, like)
    for step, reason in skipped:
        print(f"WARNING: skipped damaged checkpoint step_{step}: "
              f"{reason.splitlines()[0]}", file=sys.stderr)
    params = state["params"]
    print(f"loaded checkpoint step {manifest['step']}")

    mesh = make_data_mesh() if args.shard else None
    if args.shard:
        n = len(jax.devices())
        print(f"--shard: {n} device(s)" + (" — single-device fallback" if mesh is None else ""))

    # Dedicated calib split: disjoint from the train stream (and from the
    # eval split launch/eval.py scores on) by construction — see
    # data/pipeline.py.  The corpus seed must match the trainer's
    # (TrainerConfig.seed): DataConfig.seed fixes the Markov chain itself,
    # and the old default (1234) calibrated against a *different chain*
    # than the checkpoint was trained on.
    batch_fn, _ = make_batch_fn(
        DataConfig(vocab=cfg.vocab, seed=args.data_seed), cfg,
        batch=4, seq=args.seq, split="calib",
    )
    # Retried fetch: batch i is a pure function of (seed, "calib", i), so
    # restarting from an empty list after a transient storage fault
    # reproduces the exact same calibration set.
    fetcher = RetryingRunner(
        lambda acc, i: acc + [{k: jnp.asarray(v) for k, v in batch_fn(i).items()}],
        lambda: ([], 0),
        max_retries=5,
    )
    calib, _ = fetcher.run([], 0, args.calib_batches)
    if fetcher.recoveries:
        print(f"calibration fetch recovered from {fetcher.recoveries} "
              "transient fault(s)")
    pcfg = PTQConfig(
        method=args.method,
        spec=GridSpec(bits=args.bits, group_size=args.group_size or None),
        iterations=args.iterations,
        outlier_frac=args.outlier_frac,
        stream_chunk=args.stream_calib,
        shard=args.shard,
    )

    os.makedirs(args.out_dir, exist_ok=True)

    def progress(rec: dict):
        print(
            f"[{rec['stack']} p{rec['period']} b{rec['block']} "
            f"{rec['done_blocks']}/{rec['total_blocks']}] "
            f"{rec['n_linears']} linears  mean_err={rec['mean_rel_error']:.4g}  "
            f"{rec['seconds']}s"
        )
        append_record(progress_path, rec)

    qparams, report = ptq_quantize_model(
        plan, params, calib, pcfg, mesh=mesh, progress_cb=progress
    )
    ckpt.save_checkpoint(
        args.out_dir, manifest["step"],
        {"params": qparams},
        meta={"method": args.method, "bits": args.bits,
              "report": {k: float(v) for k, v in report.items()}},
    )
    import numpy as np

    errs = np.array(list(report.values()))
    print(json.dumps({
        "layers": len(report),
        "mean_rel_error": float(errs.mean()),
        "max_rel_error": float(errs.max()),
        "out_dir": args.out_dir,
    }, indent=1))


if __name__ == "__main__":
    main()
