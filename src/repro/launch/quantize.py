"""PTQ launcher: quantize a trained checkpoint with any paper method.

    PYTHONPATH=src python -m repro.launch.quantize --arch stablelm_12b \
        --reduce --ckpt-dir /tmp/repro_train --method quantease --bits 3
"""

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--out-dir", default="/tmp/repro_quant")
    ap.add_argument("--method", default="quantease",
                    choices=["rtn", "gptq", "awq", "quantease", "spqr",
                             "qe_outlier", "qe_outlier_struct"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--iterations", type=int, default=25)
    ap.add_argument("--outlier-frac", type=float, default=0.01)
    ap.add_argument("--group-size", type=int, default=0)
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.solver import PTQConfig, ptq_quantize_model
    from repro.data.pipeline import DataConfig, make_batch_fn
    from repro.dist import checkpoint as ckpt
    from repro.launch.train import reduced
    from repro.models import make_plan, param_shapes
    from repro.quant import GridSpec
    from repro.train.optimizer import AdamWConfig, adamw_init

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    plan = make_plan(cfg, 1)

    import jax

    like_params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), param_shapes(plan)
    )
    like = {"params": like_params, "opt": adamw_init(like_params, AdamWConfig())}
    state, manifest = ckpt.load_checkpoint(args.ckpt_dir, like)
    params = state["params"]
    print(f"loaded checkpoint step {manifest['step']}")

    batch_fn, _ = make_batch_fn(
        DataConfig(vocab=cfg.vocab), cfg, batch=4, seq=args.seq
    )
    calib = [
        {k: jnp.asarray(v) for k, v in batch_fn(50_000 + i).items()}
        for i in range(args.calib_batches)
    ]
    pcfg = PTQConfig(
        method=args.method,
        spec=GridSpec(bits=args.bits, group_size=args.group_size or None),
        iterations=args.iterations,
        outlier_frac=args.outlier_frac,
    )
    qparams, report = ptq_quantize_model(plan, params, calib, pcfg)
    ckpt.save_checkpoint(
        args.out_dir, manifest["step"],
        {"params": qparams},
        meta={"method": args.method, "bits": args.bits,
              "report": {k: float(v) for k, v in report.items()}},
    )
    import numpy as np

    errs = np.array(list(report.values()))
    print(json.dumps({
        "layers": len(report),
        "mean_rel_error": float(errs.mean()),
        "max_rel_error": float(errs.max()),
        "out_dir": args.out_dir,
    }, indent=1))


if __name__ == "__main__":
    main()
