"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6 family; VLM].

Backbone: Yi-34B-class decoder — 60L, d_model 7168, 56 heads (GQA kv=8,
head_dim 128), d_ff 20480, vocab 64000.  The vision tower + anyres tiling
is a STUB: ``input_specs`` provides (B, 2880, d_model) projected patch
embeddings (anyres 2×2 tiles + base → 5 × 24² patches) prepended to the
text sequence.
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llava_next_34b",
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab=64000,
        pattern=(BlockDef(kind="attn", mlp="dense"),),
        n_periods=60,
        rope_theta=5_000_000.0,
        n_prefix=2880,
    )
)
