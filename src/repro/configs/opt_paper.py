"""OPT-family configs (the paper's own eval family, Zhang et al. 2022).

Registered alongside the 10 assigned archs so the PTQ pipeline can target
the paper's models directly (sizes from the OPT paper; ReLU MLPs modeled as
non-gated GELU-free silu-less dense blocks → we keep gelu, the closest
supported activation, and learned positions like OPT).
"""

from repro.configs.base import BlockDef, ModelConfig, register


def _opt(name, L, d, h, ff, max_seq=2048):
    return register(
        ModelConfig(
            name=name,
            d_model=d,
            n_heads=h,
            n_kv_heads=h,
            head_dim=d // h,
            d_ff=ff,
            vocab=50272,
            pattern=(BlockDef(kind="attn", mlp="dense"),),
            n_periods=L,
            norm="layernorm",
            act="gelu",
            gated_mlp=False,
            pos="learned",
            max_seq=max_seq,
            tie_embeddings=True,
        )
    )


OPT_125M = _opt("opt_125m", 12, 768, 12, 3072)
OPT_350M = _opt("opt_350m", 24, 1024, 16, 4096)
OPT_1_3B = _opt("opt_1_3b", 24, 2048, 32, 8192)
OPT_6_7B = _opt("opt_6_7b", 32, 4096, 32, 16384)
OPT_66B = _opt("opt_66b", 64, 9216, 72, 36864)
