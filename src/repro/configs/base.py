"""Model configuration schema + registry for the assigned architectures.

A model is a stack of ``n_periods`` repetitions of a *period pattern* — a
tuple of :class:`BlockDef` — so heterogeneous stacks (Gemma-2's
local/global alternation, Jamba's 1:7 attn:mamba interleave with MoE every
other layer) lower to a single `jax.lax.scan` over periods with stacked
params (HLO size independent of depth; see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional

import jax.numpy as jnp

__all__ = ["BlockDef", "ModelConfig", "register", "get_config", "list_configs", "ARCH_IDS"]


@dataclasses.dataclass(frozen=True)
class BlockDef:
    kind: str = "attn"  # "attn" | "mamba"
    mlp: str = "dense"  # "dense" | "moe" | "none"
    window: Optional[int] = None  # sliding-window size (None = full)
    causal: bool = True  # False in encoder stacks
    cross: bool = False  # decoder cross-attention (enc-dec only)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str = "lm"  # "lm" | "encdec"
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 0  # 0 → d_model // n_heads
    d_ff: int = 2048
    vocab: int = 32000
    pattern: tuple = (BlockDef(),)
    n_periods: int = 2
    # attention / norms / mlp flavour
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    act: str = "silu"  # "silu" | "gelu"
    gated_mlp: bool = True
    post_norms: bool = False  # gemma2-style post-sublayer norms
    tie_embeddings: bool = False
    pos: str = "rope"  # "rope" | "learned"
    max_seq: int = 1 << 19
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # 0 → d_ff
    router_norm_topk: bool = True
    # Mamba2 (SSD)
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    # enc-dec (whisper)
    enc_pattern: tuple = ()
    n_enc_periods: int = 0
    n_frames: int = 1500
    # vlm stub (llava)
    n_prefix: int = 0
    dtype: Any = jnp.bfloat16

    # ---- derived ----
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def n_layers(self) -> int:
        return self.n_periods * len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_ngroups * self.ssm_state

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def param_count(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6·N·D roofline)."""
        return _count_params(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts counted)."""
        return _count_params(self, active_only=True)


def _attn_params(cfg: ModelConfig, cross: bool = False) -> int:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n = d * h * hd + 2 * d * kv * hd + h * hd * d  # q, k, v, o
    if cfg.qkv_bias and not cross:
        n += (h + 2 * kv) * hd
    return n


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    d = cfg.d_model
    return (2 * d * d_ff if cfg.gated_mlp else d * d_ff) + d_ff * d


def _mamba_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_nheads
    n = d * d_in_proj + cfg.conv_dim * cfg.ssm_conv + cfg.conv_dim
    n += 3 * cfg.ssm_nheads + cfg.d_inner  # A_log, D, dt_bias, gate norm
    n += cfg.d_inner * d
    return n


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.vocab * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab
    if cfg.pos == "learned":
        n += cfg.max_seq * cfg.d_model

    def block_count(b: BlockDef) -> int:
        c = 0
        if b.kind == "attn":
            c += _attn_params(cfg) + cfg.d_model  # + ln
            if b.cross:
                c += _attn_params(cfg, cross=True) + cfg.d_model
        else:
            c += _mamba_params(cfg) + cfg.d_model
        if b.mlp == "dense":
            c += _mlp_params(cfg, cfg.d_ff) + cfg.d_model
        elif b.mlp == "moe":
            e = cfg.top_k if active_only else cfg.n_experts
            c += cfg.d_model * cfg.n_experts  # router
            c += e * _mlp_params(cfg, cfg.moe_ff) + cfg.d_model
        return c

    n += cfg.n_periods * sum(block_count(b) for b in cfg.pattern)
    n += cfg.n_enc_periods * sum(block_count(b) for b in cfg.enc_pattern)
    return n


# --------------------------- registry ---------------------------------------

ARCH_IDS = (
    "stablelm_12b",
    "gemma2_27b",
    "qwen15_32b",
    "phi3_mini_3_8b",
    "whisper_large_v3",
    "jamba_1_5_large",
    "olmoe_1b_7b",
    "mixtral_8x22b",
    "mamba2_2_7b",
    "llava_next_34b",
)

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_").replace(".", "_")
    if name not in _REGISTRY:
        try:
            importlib.import_module(f"repro.configs.{name}")
        except ModuleNotFoundError:
            # family modules registering several configs (paper's OPT family)
            importlib.import_module("repro.configs.opt_paper")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    for arch in ARCH_IDS:
        importlib.import_module(f"repro.configs.{arch}")
    return sorted(_REGISTRY)
