"""Gemma-2-27B [arXiv:2408.00118; dense].

46L, d_model 4608, 32 heads (GQA kv=16, head_dim 128), d_ff 36864,
vocab 256000.  Local(4096-window)/global alternating attention, logit
softcap 30, attention softcap 50, GeGLU, pre+post sublayer RMSNorms,
tied embeddings.
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma2_27b",
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab=256000,
        pattern=(
            BlockDef(kind="attn", mlp="dense", window=4096),  # local
            BlockDef(kind="attn", mlp="dense", window=None),  # global
        ),
        n_periods=23,
        rope_theta=10_000.0,
        logit_softcap=30.0,
        attn_softcap=50.0,
        act="gelu",
        post_norms=True,
        tie_embeddings=True,
    )
)
