"""Jamba-1.5-Large (398B total / 94B active) [arXiv:2403.19887; hybrid].

72L, d_model 8192, 64 heads (GQA kv=8, head_dim 128), d_ff 24576,
vocab 65536; MoE 16 experts top-2 on every other layer; attention on every
8th layer (1:7 attn:mamba interleave).  Period of 8 = [attn, 7×mamba] with
MoE on odd in-period indices (4 MoE layers / period → every other layer).
No positional embeddings (the Mamba layers carry position).

TPU adaptation note (DESIGN.md §3): the SSM layers use the Mamba-2 SSD
chunked formulation (matmul-heavy, MXU-friendly) rather than Mamba-1's
sequential selective scan.
"""

from repro.configs.base import BlockDef, ModelConfig, register

_PERIOD = tuple(
    BlockDef(
        kind="attn" if i == 0 else "mamba",
        mlp="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = register(
    ModelConfig(
        name="jamba_1_5_large",
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab=65536,
        pattern=_PERIOD,
        n_periods=9,
        pos="none",
        n_experts=16,
        top_k=2,
        moe_d_ff=24576,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
    )
)
