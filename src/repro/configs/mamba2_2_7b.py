"""Mamba2-2.7B [arXiv:2405.21060; attention-free SSM].

64L, d_model 2560, d_inner 5120 (expand 2), headdim 64 (80 SSD heads),
ssm_state 128, vocab 50280.  Pure SSD (state-space duality) blocks — no
attention, no MLP (the Mamba block IS the mixer+channel mixer).
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2_2_7b",
        d_model=2560,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        pattern=(BlockDef(kind="mamba", mlp="none"),),
        n_periods=64,
        pos="none",
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        tie_embeddings=True,
    )
)
