"""Mixtral-8x22B [arXiv:2401.04088; MoE].

56L, d_model 6144, 48 heads (GQA kv=8, head_dim 128), per-expert d_ff
16384, vocab 32768; 8 experts top-2 (softmax over selected logits);
sliding-window attention (4096) per the assignment note.
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mixtral_8x22b",
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=32768,
        pattern=(BlockDef(kind="attn", mlp="moe", window=4096),),
        n_periods=56,
        rope_theta=1_000_000.0,
        n_experts=8,
        top_k=2,
        router_norm_topk=True,
    )
)
