"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B family; dense].

64L, d_model 5120, 40 heads (GQA kv=40 — i.e. MHA, head_dim 128),
d_ff 27392, vocab 152064.  QKV bias (the Qwen signature), SwiGLU.
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen15_32b",
        d_model=5120,
        n_heads=40,
        n_kv_heads=40,
        head_dim=128,
        d_ff=27392,
        vocab=152064,
        pattern=(BlockDef(kind="attn", mlp="dense"),),
        n_periods=64,
        rope_theta=1_000_000.0,
        qkv_bias=True,
    )
)
