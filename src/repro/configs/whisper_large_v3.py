"""Whisper-large-v3 [arXiv:2212.04356; audio enc-dec].

32 encoder + 32 decoder layers ("32L" in the assignment refers to the
per-stack depth of the large model), d_model 1280, 20 heads (kv=20,
head_dim 64), d_ff 5120, vocab 51866.  LayerNorm + plain (non-gated) GELU
MLPs, learned positions.  The conv frontend is a STUB: ``input_specs``
feeds precomputed (B, 1500, d_model) frame embeddings (see launch/specs.py).
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper_large_v3",
        family="encdec",
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab=51866,
        pattern=(BlockDef(kind="attn", mlp="dense", cross=True),),
        n_periods=32,
        enc_pattern=(BlockDef(kind="attn", mlp="dense", causal=False),),
        n_enc_periods=32,
        norm="layernorm",
        act="gelu",
        gated_mlp=False,
        pos="learned",
        max_seq=1 << 16,
        n_frames=1500,
    )
)
