"""StableLM-2-12B [hf:stabilityai/stablelm-2-1_6b family; dense].

40L, d_model 5120, 32 heads (GQA kv=8, head_dim 160), d_ff 13824,
vocab 100352.  Plain pre-norm SwiGLU decoder.
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="stablelm_12b",
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=160,
        d_ff=13824,
        vocab=100352,
        pattern=(BlockDef(kind="attn", mlp="dense"),),
        n_periods=40,
        rope_theta=10_000.0,
    )
)
