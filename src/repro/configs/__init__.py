"""Architecture configs (assigned pool + paper-family models)."""

from repro.configs.base import (
    ARCH_IDS,
    BlockDef,
    ModelConfig,
    get_config,
    list_configs,
    register,
)

__all__ = ["ARCH_IDS", "BlockDef", "ModelConfig", "get_config", "list_configs", "register"]
