"""OLMoE-1B-7B [arXiv:2409.02060; MoE].

16L, d_model 2048, 16 heads (kv=16, head_dim 128), per-expert d_ff 1024,
vocab 50304; 64 experts, top-8 (softmax-then-topk, no renorm).
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="olmoe_1b_7b",
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab=50304,
        pattern=(BlockDef(kind="attn", mlp="moe"),),
        n_periods=16,
        rope_theta=10_000.0,
        n_experts=64,
        top_k=8,
        router_norm_topk=False,
    )
)
