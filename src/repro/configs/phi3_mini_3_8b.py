"""Phi-3-mini-3.8B [arXiv:2404.14219; dense].

32L, d_model 3072, 32 heads (kv=32, head_dim 96), d_ff 8192, vocab 32064.
RoPE + SwiGLU + GQA(=MHA here).
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="phi3_mini_3_8b",
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab=32064,
        pattern=(BlockDef(kind="attn", mlp="dense"),),
        n_periods=32,
        rope_theta=10_000.0,
    )
)
