"""Per-layer sensitivity probing for the mixed-precision auto-tuner.

The allocator (tune/allocate.py) needs, per quantizable layer:

  * an **error table** — the layer's reconstruction error at each candidate
    bit-width (and, optionally, with an outlier budget attached), measured
    on the calibration stream with error propagation across blocks exactly
    as the production solve will see it,
  * a **sensitivity weight** — λ_max of the layer's calibration Gram Σ
    (``core/outlier.py:power_lambda_max``; the top of the activation
    spectrum, i.e. how strongly this layer's weight error is amplified into
    activation error — the high-impact signal of arXiv 2511.17801's
    layer-wise allocation),
  * its **size** (number of weights) — the budget denominator.

All three come out of cheap probe passes through the whole-model PTQ driver
itself (``core/solver.py``): one RTN pass per candidate bit-width (RTN needs
no CD iterations; its per-layer relative error orders layers the same way
the full solve does, and the driver's quantized-prefix error propagation is
identical), with λ_max collected on the first pass via
``PTQConfig.collect_sensitivity``.  Per-layer errors arrive **unrounded**
through the solver's ``progress_cb`` ``layer_errors`` records — never
through any downstream-rounded report aggregate.

MoE leaves probe per expert (the solver reports ``…/w_up.e{i}``) but
allocate per *leaf*: one (bits, outlier) choice per parameter tensor, the
same granularity ``PTQConfig.layer_specs`` overrides at.  Expert stats
aggregate by mean.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

__all__ = ["LayerStat", "probe_layer_stats"]

_EXPERT_RE = re.compile(r"\.e\d+$")


@dataclasses.dataclass
class LayerStat:
    """Probe summary for one quantizable leaf (solver layer path key)."""

    key: str  # e.g. "dec.p0.b1/wq" — PTQConfig.layer_specs granularity
    n_weights: int  # q·p (×E for MoE leaves: budget counts every expert)
    lambda_max: float  # λ_max(Σ), power iteration; MoE: mean over experts
    err: dict = dataclasses.field(default_factory=dict)
    # err[bits]          -> relative reconstruction error at that width
    # err[(bits, frac)]  -> with an outlier budget attached (optional probes)


def _leaf_key(report_key: str) -> str:
    """Collapse per-expert report keys onto their leaf path."""
    return _EXPERT_RE.sub("", report_key)


def _leaf_sizes(plan, params) -> dict:
    """n_weights per quantizable leaf path, from the dense param tree."""
    from repro.core.solver import QUANTIZABLE

    cfg = plan.cfg
    sizes: dict[str, int] = {}

    def walk(stack, pattern, n_periods, stack_name):
        for i, _b in enumerate(pattern):
            blk = stack[f"b{i}"]
            for name, leaf in blk.items():
                if name not in QUANTIZABLE or not hasattr(leaf, "shape"):
                    continue
                # stacked leading period axis
                per_period = int(np.prod(leaf.shape)) // n_periods
                for period in range(n_periods):
                    sizes[f"{stack_name}.p{period}.b{i}/{name}"] = per_period

    walk(params["dec"], cfg.pattern, cfg.n_periods, "dec")
    if "enc" in params and getattr(cfg, "n_enc_periods", 0):
        walk(params["enc"], cfg.enc_pattern, cfg.n_enc_periods, "enc")
    return sizes


def probe_layer_stats(
    plan,
    params,
    calib: list,
    *,
    bits_candidates: tuple = (2, 3, 4, 8),
    outlier_cells: tuple = (),  # ((bits, frac), ...) optional extra probes
    outlier_iterations: int = 4,
    progress_cb=None,
) -> dict:
    """Run the probe passes; returns ``{leaf_key: LayerStat}``.

    ``outlier_cells`` adds qe_outlier probes (these do run CD iterations —
    keep the list short; the default allocator only needs them when outlier
    upgrades are enabled).
    """
    from repro.core.solver import PTQConfig, ptq_quantize_model
    from repro.quant import GridSpec

    stats: dict[str, LayerStat] = {}
    sizes = _leaf_sizes(plan, params)

    def fold(records: list, label):
        errs: dict[str, list] = {}
        lams: dict[str, list] = {}
        for rec in records:
            for k, v in rec.get("layer_errors", {}).items():
                errs.setdefault(_leaf_key(k), []).append(v)
            for k, v in rec.get("lambda_max", {}).items():
                lams.setdefault(_leaf_key(k), []).append(v)
        for k, vs in errs.items():
            st = stats.get(k)
            if st is None:
                st = stats[k] = LayerStat(
                    key=k, n_weights=sizes.get(k, 0), lambda_max=0.0
                )
            st.err[label] = float(np.mean(vs))
        for k, vs in lams.items():
            if k in stats:
                stats[k].lambda_max = float(np.mean(vs))

    for j, bits in enumerate(bits_candidates):
        records: list = []
        cfg = PTQConfig(
            method="rtn",
            spec=GridSpec(bits=bits),
            collect_sensitivity=(j == 0),  # λ_max is bits-independent
        )
        ptq_quantize_model(plan, params, calib, cfg, progress_cb=records.append)
        fold(records, bits)
        if progress_cb:
            progress_cb({"probe": f"rtn@{bits}", "layers": len(stats)})

    for bits, frac in outlier_cells:
        records = []
        cfg = PTQConfig(
            method="qe_outlier",
            spec=GridSpec(bits=bits),
            outlier_frac=frac,
            iterations=outlier_iterations,
        )
        ptq_quantize_model(plan, params, calib, cfg, progress_cb=records.append)
        fold(records, (bits, frac))
        if progress_cb:
            progress_cb({"probe": f"qe_outlier@{bits}/f{frac}", "layers": len(stats)})

    return stats
