"""Accuracy-driven tuning loop: probe → allocate → re-quantize → eval.

``tune_model`` closes the loop the ROADMAP asks for: candidate per-layer
allocations (tune/allocate.py, fed by tune/sensitivity.py probes) are
re-quantized through the whole-model PTQ driver with
``PTQConfig.layer_specs`` overrides, restacked into the **serving** layout
(``serve/qparams.py`` — the heterogeneous-bits harmonized artifact), and
scored with the eval harness's scorer on the eval stream.  The *uniform*
allocation at the budget width is always one of the candidates, so the
returned winner is never worse than uniform quantization at equal average
bits — the eval subsystem acting as the optimizer's objective, not a
report generator.

Candidate evaluation is resumable at candidate granularity: callers pass
``start`` (how many candidates a previous run already finished) and a
``result_cb`` that persists each result as it lands (launch/tune.py writes
progress.jsonl records and wraps the loop in dist/elastic.RetryingRunner).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.tune.allocate import (
    AllocConfig,
    Allocation,
    allocate,
    allocation_layer_specs,
)
from repro.tune.sensitivity import probe_layer_stats

__all__ = [
    "TuneConfig",
    "tune_model",
    "build_candidates",
    "quantize_candidate",
    "evaluate_candidate",
]


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    budget_avg_bits: float = 3.0
    bits_candidates: tuple = (2, 3, 4, 8)
    outlier_frac_candidates: tuple = ()  # e.g. (0.01,)
    policies: tuple = ("sensitivity", "error")
    method: str = "quantease"  # final-quantize CD method
    iterations: int = 10  # final-quantize CD iterations
    awq_prepass: bool = False  # auto-alpha rescale via awq_then_quantease
    group_size: Optional[int] = None
    percdamp: float = 0.01
    n_ppl_batches: int = 2  # eval objective budget per candidate
    chunk: int = 64  # scorer head chunk
    probe_outlier_iterations: int = 4

    def uniform_bits(self) -> int:
        """Widest candidate not exceeding the budget — the uniform baseline
        at equal average bits."""
        fits = [b for b in self.bits_candidates if b <= self.budget_avg_bits + 1e-9]
        if not fits:
            raise ValueError(
                f"budget {self.budget_avg_bits} below every candidate width"
            )
        return max(fits)


def build_candidates(stats: dict, tcfg: TuneConfig) -> list:
    """Deterministic candidate list; index = resume position.

    Candidate 0 is always the uniform-at-budget baseline.
    """
    cands = [{
        "label": f"uniform@{tcfg.uniform_bits()}b",
        "kind": "uniform",
        "bits": tcfg.uniform_bits(),
    }]
    for policy in tcfg.policies:
        acfg = AllocConfig(
            budget_avg_bits=tcfg.budget_avg_bits,
            bits_candidates=tcfg.bits_candidates,
            outlier_frac_candidates=tcfg.outlier_frac_candidates,
            policy=policy,
        )
        alloc = allocate(stats, acfg)
        cands.append({
            "label": f"greedy-{policy}",
            "kind": "mixed",
            "allocation": alloc,
        })
    return cands


def quantize_candidate(plan, params, calib, cand: dict, tcfg: TuneConfig):
    """PTQ one candidate → restacked serving params + layer error report."""
    from repro.core.solver import PTQConfig, ptq_quantize_model
    from repro.quant import GridSpec
    from repro.serve.qparams import quantize_params_for_serving

    method = "awq_qe" if tcfg.awq_prepass else tcfg.method
    if cand["kind"] == "uniform":
        cfg = PTQConfig(
            method=method,
            spec=GridSpec(bits=cand["bits"], group_size=tcfg.group_size),
            iterations=tcfg.iterations,
            percdamp=tcfg.percdamp,
            emit="qt",
        )
    else:
        alloc: Allocation = cand["allocation"]
        cfg = PTQConfig(
            method=method,
            spec=GridSpec(bits=tcfg.uniform_bits(), group_size=tcfg.group_size),
            iterations=tcfg.iterations,
            percdamp=tcfg.percdamp,
            emit="qt",
            layer_specs=allocation_layer_specs(alloc, base_method=method),
        )
    qp, rep = ptq_quantize_model(plan, params, calib, cfg)
    return quantize_params_for_serving(plan, params, qp["dec"]), rep


def _candidate_avg_bits(cand: dict) -> float:
    if cand["kind"] == "uniform":
        return float(cand["bits"])
    return cand["allocation"].avg_bits


def evaluate_candidate(
    plan, params, calib, batch_fn, cand: dict, tcfg: TuneConfig, *, scorer=None
) -> dict:
    """Quantize + score one candidate on the eval stream (serving bytes)."""
    from repro.eval.scorer import make_scorer, perplexity_on_stream

    qp, rep = quantize_candidate(plan, params, calib, cand, tcfg)
    scorer = scorer if scorer is not None else make_scorer(plan, chunk=tcfg.chunk)
    out = perplexity_on_stream(
        plan, qp, batch_fn, n_batches=tcfg.n_ppl_batches, scorer=scorer
    )
    res = {
        "label": cand["label"],
        "kind": cand["kind"],
        "avg_bits": round(_candidate_avg_bits(cand), 4),
        "ppl": float(out["ppl"]),
        "nll": float(out["nll"]),
        "mean_layer_err": float(np.mean(list(rep.values()))),
    }
    if cand["kind"] == "mixed":
        alloc: Allocation = cand["allocation"]
        hist: dict[int, int] = {}
        for b in alloc.bits.values():
            hist[b] = hist.get(b, 0) + 1
        res["bits_histogram"] = {str(k): v for k, v in sorted(hist.items())}
        res["n_outlier_layers"] = len(alloc.outlier_frac)
        res["n_upgrades"] = alloc.n_upgrades
    return res


def tune_model(
    plan,
    params,
    calib: list,
    batch_fn,
    tcfg: TuneConfig,
    *,
    stats: Optional[dict] = None,
    prior_results: Optional[list] = None,
    result_cb: Optional[Callable[[dict], None]] = None,
    runner_factory: Optional[Callable] = None,
    progress_cb: Optional[Callable[[dict], None]] = None,
) -> dict:
    """The full loop; returns the tuning document (see bench_tune schema).

    ``stats``: pre-computed probe stats (skips probing — the resume path).
    ``prior_results``: per-candidate results already finished by a previous
    run; evaluation resumes after them.  ``result_cb`` fires once per newly
    evaluated candidate (persistence hook).  ``runner_factory(step_fn,
    restore_fn)`` may wrap candidate evaluation in a crash-recovery runner
    (dist/elastic.RetryingRunner signature); default runs the plain loop.
    """
    from repro.eval.scorer import make_scorer

    if stats is None:
        outlier_cells = tuple(
            (tcfg.bits_candidates[0], f) for f in tcfg.outlier_frac_candidates
        )
        stats = probe_layer_stats(
            plan, params, calib,
            bits_candidates=tcfg.bits_candidates,
            outlier_cells=outlier_cells,
            outlier_iterations=tcfg.probe_outlier_iterations,
            progress_cb=progress_cb,
        )
    cands = build_candidates(stats, tcfg)
    results = list(prior_results or [])
    start = len(results)
    scorer = make_scorer(plan, chunk=tcfg.chunk)

    def step_fn(state, i):
        res = evaluate_candidate(
            plan, params, calib, batch_fn, cands[i], tcfg, scorer=scorer
        )
        state.append(res)
        if result_cb:
            result_cb(res)
        if progress_cb:
            progress_cb({"candidate": res["label"], "ppl": res["ppl"]})
        return state

    if runner_factory is not None:
        def restore_fn():
            # Crash mid-candidate: nothing partial persisted — retry it.
            return results, len(results)

        runner = runner_factory(step_fn, restore_fn)
        results, _ = runner.run(results, start, len(cands) - start)
    else:
        for i in range(start, len(cands)):
            results = step_fn(results, i)

    uniform = next(r for r in results if r["kind"] == "uniform")
    best = min(results, key=lambda r: (r["ppl"], r["label"]))
    return {
        "budget_avg_bits": tcfg.budget_avg_bits,
        "bits_candidates": list(tcfg.bits_candidates),
        "outlier_frac_candidates": list(tcfg.outlier_frac_candidates),
        "method": "awq_qe" if tcfg.awq_prepass else tcfg.method,
        "iterations": tcfg.iterations,
        "n_layers": len(stats),
        "candidates": results,
        "uniform": uniform,
        "best": best,
    }
