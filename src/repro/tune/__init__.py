"""Accuracy-driven per-layer auto-tuning for whole-model quantization.

The production PTQ question the uniform grid harness can't answer: given a
global storage budget (average bits per weight, COO outliers priced at 48
bits each), which layers get 2 bits and which get 8?  This package closes
the loop end to end:

  * :mod:`repro.tune.sensitivity` — per-layer error tables + λ_max(Σ)
    probes through the whole-model PTQ driver,
  * :mod:`repro.tune.allocate` — deterministic greedy marginal-error
    descent under the budget (prefix semantics: never over budget,
    monotone in the budget),
  * :mod:`repro.tune.search` — candidate allocations (uniform baseline
    always included) re-quantized with ``PTQConfig.layer_specs`` and
    scored by the eval harness on the restacked *serving* artifact bytes.

``launch/tune.py`` is the resumable CLI; ``benchmarks/bench_tune.py``
commits the BENCH_tune.json trajectory (auto-tuned mixed precision ≤
uniform perplexity at equal average bits).
"""

from repro.tune.allocate import AllocConfig, Allocation, allocate, allocation_layer_specs
from repro.tune.search import (
    TuneConfig,
    build_candidates,
    evaluate_candidate,
    quantize_candidate,
    tune_model,
)
from repro.tune.sensitivity import LayerStat, probe_layer_stats

__all__ = [
    "AllocConfig",
    "Allocation",
    "allocate",
    "allocation_layer_specs",
    "LayerStat",
    "probe_layer_stats",
    "TuneConfig",
    "build_candidates",
    "evaluate_candidate",
    "quantize_candidate",
    "tune_model",
]
