"""Budgeted per-layer bit/outlier allocation: greedy marginal-error descent.

Given per-layer probe stats (tune/sensitivity.py) and a global budget in
**average bits per weight**, choose one (bits, outlier_frac) per layer.

Policy (the layer-wise high-impact allocation of arXiv 2511.17801, with
CDQuant's greedy coordinate-selection flavor applied at layer granularity):

  1. Every layer starts at the *lowest* candidate width.
  2. Each layer contributes a chain of **upgrades** (2→3→4→8 bits, plus
     optional "attach an outlier budget" steps).  An upgrade's *gain* is
     the probed error reduction, weighted by the chosen policy
     (``error``: raw relative error × layer size; ``sensitivity``:
     additionally × λ_max(Σ), the activation-spectrum amplification); its
     *cost* is the extra storage in bits (Δbits·n, or frac·48·n for COO
     outliers — 16-bit value + 32-bit flat index, the paper's §5.4
     accounting).
  3. Upgrades merge into one deterministic **priority sequence** by gain
     density (gain/cost), heap-ordered so a layer's chain order is
     respected; ties break on (layer key, step index) so the sequence —
     and therefore the allocation — is reproducible bit-for-bit.
  4. The budget is spent as a **prefix** of that sequence: walk it in
     order and stop at the first upgrade that no longer fits.

Prefix semantics buy the allocator its contract (tests/test_property.py):
the sequence itself is budget-independent, so a larger budget takes a
strictly longer prefix — the allocation **never exceeds the budget**, is
**deterministic**, and total assigned bits is **monotone non-decreasing in
the budget**.  (First-fit skipping would occasionally pack the budget
tighter but breaks monotonicity; the slack left behind is at most one
upgrade step.)
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

__all__ = ["AllocConfig", "Allocation", "allocate", "allocation_layer_specs"]

OUTLIER_BITS = 16 + 32  # fp16 value + int32 flat index per COO outlier


@dataclasses.dataclass(frozen=True)
class AllocConfig:
    budget_avg_bits: float = 3.0
    bits_candidates: tuple = (2, 3, 4, 8)  # ascending
    outlier_frac_candidates: tuple = ()  # e.g. (0.01,); each an upgrade step
    policy: str = "sensitivity"  # "sensitivity" | "error"

    def __post_init__(self):
        if tuple(sorted(self.bits_candidates)) != tuple(self.bits_candidates):
            raise ValueError("bits_candidates must be ascending")
        if not self.bits_candidates:
            raise ValueError("need at least one bits candidate")
        if self.policy not in ("sensitivity", "error"):
            raise ValueError(f"unknown policy {self.policy!r}")


@dataclasses.dataclass
class Allocation:
    """Result: per-layer choices + accounting."""

    bits: dict  # key -> int
    outlier_frac: dict  # key -> float (only layers with a budget attached)
    avg_bits: float  # achieved Σ(bits_l + 48·frac_l)·n_l / Σ n_l
    budget_avg_bits: float
    n_upgrades: int
    trace: list  # applied upgrade labels, in order
    total_bits: float = 0.0  # Σ assigned storage bits (weights + outliers)


def _weight(st, policy: str) -> float:
    w = float(st.n_weights)
    if policy == "sensitivity":
        w *= max(st.lambda_max, 0.0)
    return w


def _upgrade_chains(st, cfg: AllocConfig) -> dict:
    """This layer's ordered upgrade chains: two independent ladders.

    The bits ladder (2→3→4→8) must apply in order, but attaching a COO
    outlier budget is additive and valid at any assigned width — keeping it
    behind the full bits ladder in one chain would make cheap high-gain
    outlier upgrades unreachable until every width upgrade fits.  Each chain
    entry is (gain, cost_bits, label, target)."""
    bits_chain, outl_chain = [], []
    bc = cfg.bits_candidates
    w = _weight(st, cfg.policy)
    for lo, hi in zip(bc[:-1], bc[1:]):
        if lo not in st.err or hi not in st.err:
            continue
        gain = max(float(st.err[lo]) - float(st.err[hi]), 0.0) * w
        cost = float(hi - lo) * st.n_weights
        bits_chain.append((gain, cost, f"{st.key}:{lo}->{hi}b", ("bits", hi)))
    for frac in cfg.outlier_frac_candidates:
        # Probed at the lowest width (where outliers bite hardest, §5.4);
        # the COO correction is additive, so the upgrade is valid at any
        # assigned width — the gain estimate is simply most faithful low.
        key = (bc[0], frac)
        if key not in st.err or bc[0] not in st.err:
            continue
        gain = max(float(st.err[bc[0]]) - float(st.err[key]), 0.0) * w
        cost = frac * OUTLIER_BITS * st.n_weights
        outl_chain.append(
            (gain, cost, f"{st.key}:+outliers@{frac}", ("outlier", frac))
        )
    return {"bits": bits_chain, "outlier": outl_chain}


def upgrade_sequence(stats: dict, cfg: AllocConfig) -> list:
    """The budget-independent priority sequence over all layers.

    Heap-ordered by gain density (desc), chain order preserved per layer,
    ties broken on (key, step idx) — fully deterministic for a given stats
    dict (iteration order of ``stats`` does not matter: the heap key is
    value-based).
    """
    chains = {
        (k, kind): chain
        for k in sorted(stats)
        for kind, chain in _upgrade_chains(stats[k], cfg).items()
    }
    heap = []  # (-density, chain_key, step_idx, gain, cost, label, target)

    def push(ck, idx):
        chain = chains[ck]
        if idx >= len(chain):
            return
        gain, cost, label, target = chain[idx]
        density = gain / cost if cost > 0 else 0.0
        heapq.heappush(heap, (-density, ck, idx, gain, cost, label, target))

    for ck in chains:
        push(ck, 0)
    seq = []
    while heap:
        _, ck, idx, gain, cost, label, target = heapq.heappop(heap)
        seq.append({"key": ck[0], "gain": gain, "cost": cost,
                    "label": label, "target": target})
        push(ck, idx + 1)
    return seq


def allocate(stats: dict, cfg: AllocConfig) -> Allocation:
    """Spend ``budget_avg_bits`` across layers; see module docstring."""
    total_n = sum(st.n_weights for st in stats.values())
    if total_n <= 0:
        raise ValueError("no layers to allocate (empty stats)")
    base = float(cfg.bits_candidates[0])
    budget_bits = cfg.budget_avg_bits * total_n
    used = base * total_n
    if used > budget_bits + 1e-9:
        raise ValueError(
            f"budget {cfg.budget_avg_bits} below the floor width "
            f"{cfg.bits_candidates[0]}"
        )
    bits = {k: cfg.bits_candidates[0] for k in stats}
    outl: dict[str, float] = {}
    trace = []
    for up in upgrade_sequence(stats, cfg):
        if used + up["cost"] > budget_bits + 1e-9:
            break  # prefix semantics: stop, never skip-and-continue
        used += up["cost"]
        kind, val = up["target"]
        if kind == "bits":
            bits[up["key"]] = val
        else:
            outl[up["key"]] = val
        trace.append(up["label"])
    return Allocation(
        bits=bits,
        outlier_frac=outl,
        avg_bits=used / total_n,
        budget_avg_bits=cfg.budget_avg_bits,
        n_upgrades=len(trace),
        trace=trace,
        total_bits=used,
    )


def allocation_layer_specs(
    alloc: Allocation, *, base_method: str = "quantease",
    outlier_method: str = "qe_outlier",
) -> dict:
    """Convert an Allocation into ``PTQConfig.layer_specs`` overrides."""
    from repro.core.solver import LayerSpec

    specs = {}
    for key, b in alloc.bits.items():
        frac = alloc.outlier_frac.get(key)
        if frac:
            specs[key] = LayerSpec(bits=b, outlier_frac=frac, method=outlier_method)
        else:
            specs[key] = LayerSpec(bits=b, method=base_method)
    return specs
